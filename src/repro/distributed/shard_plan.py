"""Parameter + activation sharding plans (FSDP + TP + EP + SP).

Mesh axes: ``("data", "model")`` per pod, ``("pod", "data", "model")``
across pods.  The plan:

* **TP** over ``"model"``: attention heads, MLP hidden, vocab, MoE
  experts, SSD heads.  The pad plan guarantees every sharded axis
  divides 16.
* **FSDP** over ``"data"``: the non-TP weight axis (usually d_model) is
  sharded so parameter + optimizer memory scales down with the pod;
  GSPMD inserts the all-gathers (ZeRO-3 style).
* **DP** over ``"pod"``: parameters replicated across pods (gradient
  all-reduce rides the DCN), batch sharded over ``pod × data``.
* **SP** (long_500k): with batch=1 nothing shards over ``data`` — the
  rule set moves the KV/sequence axis there instead.

KV projections when ``kv_rep > 1`` (fewer logical KV heads than TP) are
model-axis-replicated; the replicated physical KV activations then shard
cleanly — Megatron-style GQA replication, charged honestly in roofline.
"""
from __future__ import annotations

import re
from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.api import AxisRules, make_rules
from repro.models import model_zoo as zoo

FSDP = "data"
TP = "model"


def default_rules(*, multi_pod: bool = False,
                  seq_parallel: bool = False) -> AxisRules:
    dp: Tuple[str, ...] = ("pod", "data") if multi_pod else ("data",)
    return make_rules(
        batch=None if seq_parallel else dp,
        seq=None,
        kv_seq=dp if seq_parallel else None,
        heads=TP, kv_heads=TP, ff=TP, vocab=TP, experts=TP,
        embed=None)


# ---------------------------------------------------------------------------
# Parameter specs by pytree path
# ---------------------------------------------------------------------------
def _param_spec(path: str, ndim: int, model: zoo.Model) -> P:
    """Spec for one parameter, identified by its '/'-joined path."""
    kv_rep = model.plan.kv_rep
    st = model.settings
    fsdp_ax = FSDP if st.fsdp_params else None
    stacked = path.startswith(("layers/", "enc_layers/", "dec_layers/",
                               "shared_attn/"))
    lead: Tuple = (None,) if stacked else ()

    def spec(*axes):
        axes = tuple(fsdp_ax if a is FSDP else a for a in axes)
        return P(*(lead + axes))

    name = path.split("/", 1)[1] if stacked else path

    # -- embeddings / positions ----------------------------------------
    if name == "embed/table":
        # "vocab": Megatron vocab-parallel (gather + cross-model reshard)
        # "fsdp":  d_model over data, vocab replicated (cheap gather; the
        #          §Perf lever for collective-bound small models).
        # Tied embeddings keep vocab-parallel: the same table feeds the
        # logits matmul, which must stay vocab-sharded or the logits
        # blow past HBM.
        if st.embed_shard == "fsdp" and not model.cfg.tie_embeddings:
            return P(None, FSDP)
        return P(TP, FSDP)
    if name == "unembed/table":
        return P(TP, FSDP)   # logits matmul wants vocab over model
    if name in ("pos", "enc_pos", "dec_pos"):
        return P(None, None)

    # -- norms ------------------------------------------------------------
    if re.search(r"(ln\w*|final_norm|enc_norm)/(scale|bias)$", path):
        return spec(None) if stacked else P(None)
    if name.endswith(("q_norm/scale", "k_norm/scale")):
        return spec(None)

    # -- attention --------------------------------------------------------
    if name.endswith(("attn/wq/w", "xattn/wq/w")):
        return spec(FSDP, TP)
    if name.endswith(("attn/wk/w", "attn/wv/w", "xattn/wk/w",
                      "xattn/wv/w")):
        return spec(FSDP, None) if kv_rep > 1 else spec(FSDP, TP)
    if name.endswith(("attn/wq/b", "xattn/wq/b")):
        return spec(TP)
    if name.endswith(("attn/wk/b", "attn/wv/b", "xattn/wk/b",
                      "xattn/wv/b")):
        return spec(None) if kv_rep > 1 else spec(TP)
    if name.endswith(("attn/wo/w", "xattn/wo/w")):
        return spec(TP, FSDP)
    if name.endswith(("attn/wo/b", "xattn/wo/b")):
        return spec(None)

    # -- MLP ----------------------------------------------------------------
    if name.endswith(("mlp/gate/w", "mlp/up/w")):
        return spec(FSDP, TP)
    if name.endswith(("mlp/gate/b", "mlp/up/b")):
        return spec(TP)
    if name.endswith("mlp/down/w"):
        return spec(TP, FSDP)
    if name.endswith("mlp/down/b"):
        return spec(None)

    # -- MoE ------------------------------------------------------------------
    if name.endswith("moe/router"):
        return spec(FSDP, None)
    if name.endswith(("moe/up", "moe/gate", "moe/down")):
        return spec(TP, FSDP, None)        # expert axis -> EP over model

    # -- Mamba2 -----------------------------------------------------------
    if name.endswith("mixer/in_proj"):
        return spec(FSDP, TP)
    if name.endswith("mixer/conv_w"):
        return spec(None, TP)
    if name.endswith("mixer/conv_b"):
        return spec(TP)
    if name.endswith(("mixer/A_log", "mixer/D", "mixer/dt_bias")):
        return spec(TP)
    if name.endswith("mixer/norm_scale"):
        return spec(TP)
    if name.endswith("mixer/out_proj"):
        return spec(TP, FSDP)
    if name.endswith("ln/scale") or name.endswith("ln/bias"):
        return spec(None)

    # fallback: replicate
    return P(*([None] * ndim)) if ndim else P()


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def param_pspecs(model: zoo.Model):
    """PartitionSpec pytree matching ``init_params(model, key)``."""
    specs = zoo.param_specs(model)
    flat, treedef = jax.tree_util.tree_flatten_with_path(specs)
    out = []
    for path, leaf in flat:
        p = _param_spec(_path_str(path), len(leaf.shape), model)
        out.append(p)
    return jax.tree_util.tree_unflatten(treedef, out)


def opt_pspecs(model: zoo.Model):
    """Specs for AdamW state: mu/nu mirror params, step replicated."""
    ps = param_pspecs(model)
    return {"mu": ps, "nu": ps, "step": P()}


def ef_pspecs(model: zoo.Model, grad_compression: bool):
    if grad_compression:
        return param_pspecs(model)
    return {"_": P()}


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------
def batch_pspecs(batch, rules: AxisRules):
    """Mirror the batch dict's keys with the appropriate specs."""
    out = {}
    for key in batch:
        if key in ("tokens", "labels", "loss_mask"):
            out[key] = rules.spec("batch", "seq")
        elif key == "embeds":
            out[key] = rules.spec("batch", "seq", "embed")
        else:
            raise KeyError(key)
    return out


def cache_pspecs(model: zoo.Model, rules: AxisRules):
    """Specs matching ``zoo.cache_specs`` layouts (leading layer axis)."""
    cfg = model.cfg
    out = {"len": rules.spec("batch")}
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        kv = P(None, *rules.spec("batch", "kv_seq", "kv_heads", None))
        out["k"] = kv
        out["v"] = kv
    elif fam in ("ssm", "hybrid"):
        out["conv"] = P(None, *rules.spec("batch", None, "ff"))
        out["ssd"] = P(None, *rules.spec("batch", "heads", None, None))
        if fam == "hybrid":
            kv = P(None, *rules.spec("batch", "kv_seq", "kv_heads", None))
            out["k"] = kv
            out["v"] = kv
    elif fam in ("encdec", "audio"):
        kv = P(None, *rules.spec("batch", "kv_seq", "kv_heads", None))
        xkv = P(None, *rules.spec("batch", None, "kv_heads", None))
        out.update(k=kv, v=kv, xk=xkv, xv=xkv)
    return out


def named(mesh: Mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))
