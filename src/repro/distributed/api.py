"""Logical-axis sharding API.

Models never mention mesh axes; they annotate activations with *logical*
axis names via ``shard(x, "batch", "seq", "heads", None)``.  A rule set
(installed with ``use_rules``) maps logical names to mesh axes; with no
rules installed every call is a no-op, so CPU unit tests never touch the
mesh machinery.

This indirection is the §Perf lever: hillclimb iterations swap rule sets
(e.g. move "kv_seq" from None to "data" to enable sequence parallelism for
``long_500k``) without touching model code.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class AxisRules:
    """Mapping from logical axis name -> mesh axis (or tuple, or None)."""

    rules: Tuple[Tuple[str, MeshAxes], ...]

    def get(self, name: Optional[str]) -> MeshAxes:
        if name is None:
            return None
        for k, v in self.rules:
            if k == name:
                return v
        return None

    def spec(self, *logical: Optional[str]) -> P:
        return P(*(self.get(a) for a in logical))


def make_rules(**kw: MeshAxes) -> AxisRules:
    return AxisRules(tuple(sorted(kw.items())))


#: Default logical-axis vocabulary (see shard_plan.py for parameter rules):
#:   batch     — request/example axis            -> data (+pod)
#:   seq       — sequence axis of activations    -> None (SP: "data")
#:   kv_seq    — KV-cache sequence axis          -> None (SP for long ctx)
#:   heads     — q heads                         -> model
#:   kv_heads  — kv heads (physical, replicated) -> model
#:   ff        — MLP hidden                      -> model
#:   vocab     — vocabulary                      -> model
#:   experts   — MoE expert axis                 -> model (EP)
#:   embed     — d_model of activations          -> None
DEFAULT_LOGICAL = ("batch", "seq", "kv_seq", "heads", "kv_heads", "ff",
                   "vocab", "experts", "embed")


class _State(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[AxisRules] = None


_STATE = _State()


@contextmanager
def use_rules(mesh: Mesh, rules: AxisRules):
    prev = (_STATE.mesh, _STATE.rules)
    _STATE.mesh, _STATE.rules = mesh, rules
    try:
        yield
    finally:
        _STATE.mesh, _STATE.rules = prev


def current_rules() -> Optional[AxisRules]:
    return _STATE.rules


def current_mesh() -> Optional[Mesh]:
    return _STATE.mesh


def shard(x, *logical: Optional[str]):
    """Constrain activation sharding by logical axis names (no-op w/o rules)."""
    if _STATE.mesh is None or _STATE.rules is None:
        return x
    spec = _STATE.rules.spec(*logical)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_STATE.mesh, spec))
