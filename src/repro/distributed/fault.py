"""Fault tolerance for scale-out runs.

Training side (real runtime):
  * ``run_with_restarts`` — supervises a training loop; on a (simulated
    or real) failure it restores the latest step-atomic checkpoint and
    continues.  With ``elastic=True`` the restart may build a *smaller*
    mesh (lost pod) and reload with new shardings — the checkpoint layout
    is mesh-agnostic (see training/checkpoint.py).
  * ``StragglerDetector`` — flags iterations slower than k× the running
    median; the serving counterpart (LeastLoaded dispatch) drains slowed
    workers, and the simulator's FaultSpec injects both.

Serving side: worker failure / straggler injection and mitigation live in
``repro.core`` (Worker.fail + Simulation.redispatch) — validated in
tests/test_simulator.py.
"""
from __future__ import annotations

import statistics
from dataclasses import dataclass, field
from typing import Callable, List


@dataclass
class StragglerDetector:
    factor: float = 3.0
    window: int = 32
    _times: List[float] = field(default_factory=list)

    def record(self, seconds: float) -> bool:
        """Returns True if this iteration is a straggler."""
        self._times.append(seconds)
        if len(self._times) > self.window:
            self._times.pop(0)
        if len(self._times) < 8:
            return False
        med = statistics.median(self._times)
        return seconds > self.factor * med


class InjectedFailure(RuntimeError):
    """Raised by tests/drivers to simulate a node loss."""


def run_with_restarts(make_trainer: Callable[[], "object"],
                      num_steps: int, *, max_restarts: int = 3,
                      log=print) -> "object":
    """Supervise: build trainer (restores latest ckpt), run; on failure
    rebuild and continue from the last checkpoint.  Returns the trainer
    that finished."""
    restarts = 0
    while True:
        trainer = make_trainer()
        remaining = num_steps - trainer.step
        if remaining <= 0:
            return trainer
        try:
            trainer.run(remaining, log=log)
            return trainer
        except InjectedFailure as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            if log:
                log(f"[fault] {e}; restart {restarts}/{max_restarts} "
                    f"from step checkpoint")
