"""Pad-to-shard planning.

Several assigned configs do not divide the tensor-parallel axis (e.g.
qwen3-14b has 40 Q / 8 KV heads vs TP=16). We compute a *physical* plan:

* KV heads are replicated ``rep = tp // Hkv`` times when ``Hkv < tp``
  (Megatron-style GQA replication; requires ``Hkv | tp``). Replication
  happens on the *activation* after the KV projection, so the logical model
  (and its gradients) are exactly preserved at any tp.
* Q heads are padded so that (a) every device holds an integer number of
  heads and (b) all Q heads on a device share that device's KV head:
  per KV-copy group size ``Gp = ceil(G / rep)`` with ``G = Hq / Hkv``;
  physical ``Qp = Hkv * rep * Gp``. Padded heads are masked at the output
  projection (their gradients are exactly zero).
* Physical Q-head layout: ``[kv0.copy0 (Gp heads), kv0.copy1, ..., kv1.copy0,
  ...]``; physical q head ``i`` attends with physical kv head ``i // Gp``,
  and physical kv head ``j`` is original head ``j // rep``.
* Vocab is padded to a multiple of 256 (padded logits masked to -inf in the
  loss and sampler).
* MoE experts are padded to a multiple of the EP axis; padded experts get
  ``-inf`` router logits.
* SSD heads are padded to a multiple of tp and masked at ``out_proj``.

``tp == 1`` (all CPU tests) yields the identity plan, so smoke-test numerics
are exactly the logical model.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.configs.base import ArchConfig, pad_to


@dataclass(frozen=True)
class PadPlan:
    tp: int
    # attention
    n_q: int               # physical q heads
    n_kv: int              # physical kv heads (= logical * kv_rep)
    kv_rep: int
    group: int             # physical q heads per physical kv head (Gp)
    n_q_logical: int
    # vocab
    vocab: int
    vocab_logical: int
    # moe
    n_experts: int
    n_experts_logical: int
    # ssm
    ssm_heads: int
    ssm_heads_logical: int

    @property
    def has_q_padding(self) -> bool:
        return self.n_q != self.n_q_logical

    def q_head_mask(self) -> np.ndarray:
        """Boolean (n_q,) — True for heads that exist in the logical model.

        Layout: for each original kv head h, ``kv_rep`` copies, each with
        ``group`` physical slots; original q heads ``h*G .. h*G+G-1`` are
        distributed to copies in order (copy r holds logical q heads
        ``h*G + r*group .. min(h*G + (r+1)*group, (h+1)*G) - 1``).
        """
        if self.n_q_logical == 0:
            return np.zeros((0,), bool)
        hkv = self.n_kv // self.kv_rep
        g_logical = self.n_q_logical // max(1, hkv)
        mask = np.zeros((self.n_q,), bool)
        slot = 0
        for h in range(hkv):
            remaining = g_logical
            for _ in range(self.kv_rep):
                take = min(self.group, max(0, remaining))
                mask[slot:slot + take] = True
                remaining -= take
                slot += self.group
        assert mask.sum() == self.n_q_logical, (mask.sum(), self)
        return mask

    def ssm_head_mask(self) -> np.ndarray:
        mask = np.zeros((self.ssm_heads,), bool)
        mask[: self.ssm_heads_logical] = True
        return mask

    def expert_mask(self) -> np.ndarray:
        mask = np.zeros((self.n_experts,), bool)
        mask[: self.n_experts_logical] = True
        return mask


def make_pad_plan(cfg: ArchConfig, tp: int = 1) -> PadPlan:
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    if hq and hkv:
        if hkv >= tp:
            if hkv % tp:
                raise ValueError(f"{cfg.name}: kv heads {hkv} vs tp {tp}")
            rep = 1
        else:
            if tp % hkv:
                raise ValueError(f"{cfg.name}: kv heads {hkv} must divide tp {tp}")
            rep = tp // hkv
        g = hq // hkv
        if hq % hkv:
            raise ValueError(f"{cfg.name}: q heads {hq} not multiple of kv {hkv}")
        gp = math.ceil(g / rep)
        n_kv_p = hkv * rep
        n_q_p = n_kv_p * gp
        group = gp
    else:
        rep, group, n_kv_p, n_q_p = 1, 1, hkv, hq

    vocab_p = pad_to(cfg.vocab_size, max(256, tp)) if cfg.vocab_size else 0

    n_exp = cfg.moe.num_experts if cfg.moe else 0
    n_exp_p = pad_to(n_exp, tp) if n_exp else 0

    ssm_h = cfg.ssm.n_heads(cfg.d_model) if cfg.ssm else 0
    ssm_h_p = pad_to(ssm_h, tp) if ssm_h else 0

    return PadPlan(tp=tp,
                   n_q=n_q_p, n_kv=n_kv_p, kv_rep=rep, group=group,
                   n_q_logical=hq,
                   vocab=vocab_p, vocab_logical=cfg.vocab_size,
                   n_experts=n_exp_p, n_experts_logical=n_exp,
                   ssm_heads=ssm_h_p, ssm_heads_logical=ssm_h)
