"""AdamW with cosine schedule — pure pytree functions (no optax offline)."""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    grad_clip: float = 1.0


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_frac."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * \
        (1 + jnp.cos(math.pi * prog))
    return cfg.lr * warm * cos


def adamw_init(params) -> dict:
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    return {"mu": zeros(), "nu": zeros(),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    lr = schedule(cfg, step)
    b1, b2 = cfg.betas
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                   # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["mu"])
    flat_v = treedef.flatten_up_to(opt_state["nu"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"mu": new_m, "nu": new_v, "step": step + 1}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
