"""Training data pipeline: deterministic synthetic LM streams.

Offline container => no real corpora; the pipeline generates a seeded
Zipfian token stream with Markov structure (so the LM has learnable
signal and loss decreases), packed into fixed-length sequences.  The
interface (``DataConfig`` -> iterator of {"tokens","labels"} batches,
checkpointable cursor) is what a real loader would implement.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    markov_order: int = 1
    markov_weight: float = 0.7    # learnable structure strength


class DataPipeline:
    """Deterministic, seekable batch stream (cursor = batch index)."""

    def __init__(self, dc: DataConfig):
        self.dc = dc
        rng = np.random.RandomState(dc.seed)
        v = dc.vocab_size
        # base Zipf distribution over the vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._base = ranks ** -dc.zipf_a
        self._base /= self._base.sum()
        # a sparse deterministic successor table: tok -> preferred next
        self._succ = rng.permutation(v)
        self.cursor = 0

    def batch_at(self, idx: int) -> Dict[str, np.ndarray]:
        dc = self.dc
        rng = np.random.RandomState((dc.seed * 1_000_003 + idx) % 2 ** 31)
        b, s = dc.global_batch, dc.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.choice(dc.vocab_size, size=b, p=self._base)
        follow = rng.random_sample((b, s)) < dc.markov_weight
        rand_part = rng.choice(dc.vocab_size, size=(b, s), p=self._base)
        for t in range(s):
            nxt = np.where(follow[:, t], self._succ[toks[:, t]],
                           rand_part[:, t])
            toks[:, t + 1] = nxt
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(self.cursor)
            self.cursor += 1

    def state(self) -> dict:
        return {"cursor": self.cursor}

    def restore(self, state: dict) -> None:
        self.cursor = int(state["cursor"])
