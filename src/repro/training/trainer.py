"""Training loop: microbatched, shardable, checkpointed, restartable.

The ``train_step`` here is the exact function the multi-pod dry-run
lowers for the ``train_4k`` shapes: loss (+MoE aux) -> grad -> optional
int8 error-feedback compression -> AdamW.  Microbatching (gradient
accumulation) runs as a ``lax.scan`` over microbatches so remat keeps
activation memory flat.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import model_zoo as zoo
from repro.training import grad_compress
from repro.training.checkpoint import CheckpointManager
from repro.training.data import DataConfig, DataPipeline
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


@dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    grad_compression: bool = False
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 100
    async_checkpoint: bool = True
    log_every: int = 10


def make_train_step(model: zoo.Model, tc: TrainConfig):
    """Returns jit-able ``train_step(params, opt_state, ef_state, batch)``.

    batch: {"tokens": (B,S), "labels": (B,S)} with B divisible by
    ``tc.microbatches``.
    """

    def loss_fn(params, batch):
        return zoo.loss_fn(model, params, batch)

    def train_step(params, opt_state, ef_state, batch):
        nmb = tc.microbatches
        if nmb > 1:
            def reshape(x):
                b = x.shape[0]
                return x.reshape((nmb, b // nmb) + x.shape[1:])
            mbatches = jax.tree.map(reshape, batch)

            def mb_body(acc, mb):
                (l, m), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                acc = jax.tree.map(jnp.add, acc,
                                   jax.tree.map(
                                       lambda x: x.astype(jnp.float32), g))
                return acc, l

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gsum, losses = jax.lax.scan(mb_body, zero, mbatches)
            grads = jax.tree.map(lambda x: x / nmb, gsum)
            loss = losses.mean()
        else:
            (loss, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        if tc.grad_compression:
            grads, ef_state = grad_compress.compress_grads(grads, ef_state)
        params, opt_state, om = adamw_update(tc.opt, grads, opt_state,
                                             params)
        metrics = {"loss": loss, **om}
        return params, opt_state, ef_state, metrics

    return train_step


class Trainer:
    def __init__(self, model: zoo.Model, tc: TrainConfig, dc: DataConfig,
                 *, init_key=None, shardings=None):
        self.model = model
        self.tc = tc
        self.data = DataPipeline(dc)
        key = init_key if init_key is not None else jax.random.key(0)
        self.params = zoo.init_params(model, key)
        self.opt_state = adamw_init(self.params)
        self.ef_state = grad_compress.ef_init(self.params) \
            if tc.grad_compression else {"_": jnp.zeros(())}
        self.step = 0
        self.ckpt = CheckpointManager(tc.checkpoint_dir) \
            if tc.checkpoint_dir else None
        self._fn = jax.jit(make_train_step(model, tc))
        self.history: list = []
        if self.ckpt is not None:
            self._maybe_restore(shardings)

    # -- fault tolerance -------------------------------------------------
    def _state_tree(self):
        return {"params": self.params, "opt": self.opt_state,
                "ef": self.ef_state}

    def _maybe_restore(self, shardings=None) -> bool:
        step, tree, extra = self.ckpt.restore_latest(
            self._state_tree(), shardings=shardings)
        if step is None:
            return False
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.ef_state = tree["ef"]
        self.step = step
        if "data" in extra:
            self.data.restore(extra["data"])
        return True

    def save(self, blocking: bool = True) -> None:
        if self.ckpt is None:
            return
        self.ckpt.save(self.step, self._state_tree(),
                       blocking=blocking or not self.tc.async_checkpoint,
                       extra={"data": self.data.state()})

    # -- loop --------------------------------------------------------------
    def run(self, num_steps: int, *, log=print) -> Dict[str, float]:
        last = {}
        for _ in range(num_steps):
            batch = {k: jnp.asarray(v)
                     for k, v in self.data.batch_at(self.data.cursor).items()}
            self.data.cursor += 1
            self.params, self.opt_state, self.ef_state, m = self._fn(
                self.params, self.opt_state, self.ef_state, batch)
            self.step += 1
            last = {k: float(v) for k, v in m.items()}
            self.history.append({"step": self.step, **last})
            if log and self.step % self.tc.log_every == 0:
                log(f"step {self.step}: " +
                    " ".join(f"{k}={v:.4g}" for k, v in last.items()))
            if self.ckpt is not None and \
                    self.step % self.tc.checkpoint_every == 0:
                self.save(blocking=not self.tc.async_checkpoint)
        if self.ckpt is not None:
            self.ckpt.wait()
        return last
