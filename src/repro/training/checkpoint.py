"""Step-atomic sharded checkpointing with elastic resharding.

Fault-tolerance contract for 1000+ node runs:

* **Atomic**: a checkpoint directory is written under ``step_N.tmp`` and
  atomically renamed to ``step_N`` only after every shard file and the
  manifest (with per-tensor checksums) are fsync'd — a crash mid-write
  can never corrupt the latest checkpoint.
* **Sharded**: each host writes only the addressable shards of its
  process (here: one process, but the layout is per-shard files keyed by
  flattened path + shard index, exactly the multi-host layout).
* **Elastic**: ``load`` takes the *target* sharding (any mesh); shards
  are re-assembled to the logical array and re-sharded via
  ``jax.device_put`` — a checkpoint saved on mesh A loads on mesh B.
* **Async**: ``save(..., blocking=False)`` snapshots to host memory and
  writes on a background thread, keeping the step path clear.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def _unflatten_like(template, values: Dict[str, Any]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in values:
            raise KeyError(f"checkpoint missing tensor {key!r}")
        leaves.append(values[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _checksum(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, *, blocking: bool = True,
             extra: Optional[dict] = None) -> str:
        self.wait()                         # one async save in flight max
        host = {k: np.asarray(v) for k, v in
                _flatten_with_paths(tree).items()}

        def write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "tensors": {},
                        "extra": extra or {}}
            for key, arr in host.items():
                fname = key.replace("/", "__") + ".npy"
                logical_dtype = str(arr.dtype)
                if arr.dtype.name == "bfloat16":   # npy-safe raw view
                    arr = arr.view(np.uint16)
                np.save(os.path.join(tmp, fname), arr)
                manifest["tensors"][key] = {
                    "file": fname, "shape": list(arr.shape),
                    "dtype": logical_dtype, "sha": _checksum(arr)}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=1)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        return os.path.join(self.dir, f"step_{step}")

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def load(self, step: int, template, *, shardings=None,
             verify: bool = True):
        """Restore into the structure of ``template``; ``shardings`` (a
        matching pytree of NamedSharding / None) re-shards elastically."""
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        values = {}
        for key, meta in manifest["tensors"].items():
            arr = np.load(os.path.join(path, meta["file"]))
            if verify and _checksum(arr) != meta["sha"]:
                raise IOError(f"checksum mismatch for {key} in step {step}")
            if meta["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            values[key] = arr
        tree = _unflatten_like(template, values)
        if shardings is not None:
            flat_t, treedef = jax.tree_util.tree_flatten(tree)
            flat_s = treedef.flatten_up_to(shardings)
            flat = [jax.device_put(t, s) if s is not None else
                    jax.device_put(t) for t, s in zip(flat_t, flat_s)]
            tree = jax.tree_util.tree_unflatten(treedef, flat)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        # restore dtypes from template (np.save keeps them, but bf16
        # round-trips through numpy as a void/uint16 view guard)
        tree = jax.tree.map(
            lambda x, t: x.astype(t.dtype) if hasattr(t, "dtype") else x,
            tree, template)
        return tree, manifest.get("extra", {})

    def restore_latest(self, template, *, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None, {}
        tree, extra = self.load(step, template, shardings=shardings)
        return step, tree, extra
