"""Int8 error-feedback gradient compression for the DP all-reduce.

Distributed-optimization trick for scale-out training: gradients are
quantized to int8 with a per-tensor scale before the data-parallel
all-reduce (4x less DCN/ICI traffic across pods), and the quantization
residual is fed back into the next step's gradient (error feedback keeps
the method convergent — Seide et al. / Karimireddy et al.).

Under GSPMD the quantize/dequantize pair brackets the psum so XLA's
collective sees int8 operands; in the single-process dry-run the traffic
reduction shows up directly in the parsed collective bytes.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def ef_init(params) -> Any:
    """Error-feedback residual state (fp32 zeros like params)."""
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize(g, *, bits: int = 8) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    gf = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(gf))
    qmax = 2.0 ** (bits - 1) - 1
    scale = jnp.maximum(amax / qmax, 1e-12)
    q = jnp.clip(jnp.round(gf / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def dequantize(q, scale) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, ef_state, *, axis_name: str = None):
    """Quantize (grad + residual), optionally psum over the DP axis,
    dequantize, and compute the new residual.

    Returns (decompressed_grads, new_ef_state)."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = quantize(gf)
        if axis_name is not None:
            qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
            n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
            deq = qsum.astype(jnp.float32) * scale / n.astype(jnp.float32)
        else:
            deq = dequantize(q, scale)
        resid = gf - dequantize(q, scale)
        return deq.astype(g.dtype), resid

    out = jax.tree.map(one, grads, ef_state)
    deq = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    ef = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    return deq, ef
