from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from repro.training.trainer import TrainConfig, Trainer  # noqa: F401
