"""chameleon-34b — early-fusion token-based VLM backbone [arXiv:2405.09818].

Images are VQ-tokenized into the same vocabulary (65536 ids include the
image codebook); the backbone is a llama-style decoder with QK-Norm (which
Chameleon introduced for logit-drift stability). The VQ tokenizer itself is
a stub per the assignment: ``input_specs()`` provides token ids.
"""
from repro.configs.base import ArchConfig, VLM

CONFIG = ArchConfig(
    name="chameleon-34b",
    family=VLM,
    num_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    norm="rmsnorm",
    act="silu",
    rope_theta=10_000.0,
    subquadratic=False,
)

SMOKE = ArchConfig(
    name="chameleon-34b-smoke",
    family=VLM,
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=176,
    vocab_size=512,
    qk_norm=True,
    norm="rmsnorm",
    act="silu",
)
