"""qwen3-14b — dense decoder with QK-Norm and GQA [hf:Qwen/Qwen3 family]."""
from repro.configs.base import ArchConfig, DENSE

CONFIG = ArchConfig(
    name="qwen3-14b",
    family=DENSE,
    num_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    norm="rmsnorm",
    act="silu",
    rope_theta=1_000_000.0,
)

SMOKE = ArchConfig(
    name="qwen3-14b-smoke",
    family=DENSE,
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=160,
    vocab_size=512,
    qk_norm=True,
    norm="rmsnorm",
    act="silu",
    rope_theta=1_000_000.0,
)
