"""granite-moe-1b-a400m — token-choice MoE, 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.configs.base import ArchConfig, MoEConfig, MOE

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family=MOE,
    num_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,                 # per-expert hidden (mirrored in moe.d_expert)
    vocab_size=49155,
    moe=MoEConfig(num_experts=32, top_k=8, d_expert=512),
    norm="rmsnorm",
    act="silu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="granite-moe-1b-a400m-smoke",
    family=MOE,
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=64,
    vocab_size=384,
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=64),
    norm="rmsnorm",
    act="silu",
    tie_embeddings=True,
)
