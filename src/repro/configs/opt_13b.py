"""opt-13b — the paper's secondary model for the disaggregation ratio study
(Fig. 11). Learned positions, LayerNorm, GELU, MHA."""
from repro.configs.base import ArchConfig, DENSE

CONFIG = ArchConfig(
    name="opt-13b",
    family=DENSE,
    num_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=20480,
    vocab_size=50272,
    norm="layernorm",
    act="gelu",
    pos_emb="learned",
    max_seq_len=2048,
)

SMOKE = ArchConfig(
    name="opt-13b-smoke",
    family=DENSE,
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab_size=256,
    norm="layernorm",
    act="gelu",
    pos_emb="learned",
    max_seq_len=2048,
)
