"""stablelm-3b — dense decoder [hf:stabilityai/stablelm-2-1_6b family].

MHA (kv == q heads), LayerNorm (StableLM-2 keeps LayerNorm), SwiGLU MLP.
"""
from repro.configs.base import ArchConfig, DENSE

CONFIG = ArchConfig(
    name="stablelm-3b",
    family=DENSE,
    num_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    norm="layernorm",
    act="silu",
    rope_theta=10_000.0,
)

SMOKE = ArchConfig(
    name="stablelm-3b-smoke",
    family=DENSE,
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=192,
    vocab_size=384,
    norm="layernorm",
    act="silu",
)
