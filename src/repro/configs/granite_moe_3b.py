"""granite-moe-3b-a800m — token-choice MoE, 40 experts top-8
[hf:ibm-granite/granite-3.0-3b-a800m-base].

Note: the assignment line lists "MoE 40e top-8" in the config field and
"32 experts" in the trailing comment; we follow the config field (40), which
also matches the released granite-3.0-3b-a800m checkpoint.
"""
from repro.configs.base import ArchConfig, MoEConfig, MOE

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family=MOE,
    num_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    moe=MoEConfig(num_experts=40, top_k=8, d_expert=512),
    norm="rmsnorm",
    act="silu",
    rope_theta=10_000.0,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="granite-moe-3b-a800m-smoke",
    family=MOE,
    num_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_ff=64,
    vocab_size=384,
    moe=MoEConfig(num_experts=5, top_k=2, d_expert=64),
    norm="rmsnorm",
    act="silu",
    tie_embeddings=True,
)
