"""qwen2-0.5b — dense decoder, GQA kv=2, QKV bias, tied embeddings
[arXiv:2407.10671]."""
from repro.configs.base import ArchConfig, DENSE

CONFIG = ArchConfig(
    name="qwen2-0.5b",
    family=DENSE,
    num_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    norm="rmsnorm",
    act="silu",
    rope_theta=1_000_000.0,
)

SMOKE = ArchConfig(
    name="qwen2-0.5b-smoke",
    family=DENSE,
    num_layers=2,
    d_model=56,
    n_heads=7,
    n_kv_heads=1,
    head_dim=8,
    d_ff=128,
    vocab_size=384,
    qkv_bias=True,
    tie_embeddings=True,
    norm="rmsnorm",
    act="silu",
)
