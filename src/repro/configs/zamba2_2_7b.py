"""zamba2-2.7b — Mamba2 backbone with shared attention blocks [arXiv:2411.15242].

54 Mamba2 (SSD) layers; every ``attn_period`` layers a *shared* full
transformer block (attention + MLP, weights shared across applications) is
interleaved, following the Zamba2 design. Decode state is O(1) per request
for the SSD layers plus a small KV cache for the shared-attention
applications, so the arch is sub-quadratic and runs ``long_500k``.
"""
from repro.configs.base import ArchConfig, SSMConfig, HYBRID

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family=HYBRID,
    num_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256, n_groups=1),
    attn_period=6,          # shared attention applied every 6 SSD layers
    n_shared_attn=1,
    norm="rmsnorm",
    act="silu",
    subquadratic=True,
)

SMOKE = ArchConfig(
    name="zamba2-2.7b-smoke",
    family=HYBRID,
    num_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_width=4,
                  chunk_size=32, n_groups=1),
    attn_period=2,
    n_shared_attn=1,
    norm="rmsnorm",
    act="silu",
    subquadratic=True,
)
