"""whisper-base — encoder-decoder audio backbone [arXiv:2212.04356].

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings of shape (batch, frames, d_model) directly to
the encoder. "6L" means 6 encoder + 6 decoder layers (whisper-base).
Decode shapes use decoder self-attention KV of ``seq_len`` plus a fixed
cross-attention KV of ``enc_seq_len``.
"""
from repro.configs.base import ArchConfig, AUDIO

CONFIG = ArchConfig(
    name="whisper-base",
    family=AUDIO,
    num_layers=12,         # 6 enc + 6 dec
    n_enc_layers=6,
    n_dec_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    enc_seq_len=1500,
    norm="layernorm",
    act="gelu",
    pos_emb="learned",
    frontend="embed",
    qkv_bias=True,
    max_seq_len=1_048_576,
)

SMOKE = ArchConfig(
    name="whisper-base-smoke",
    family=AUDIO,
    num_layers=4,
    n_enc_layers=2,
    n_dec_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=384,
    enc_seq_len=32,
    norm="layernorm",
    act="gelu",
    pos_emb="learned",
    frontend="embed",
    qkv_bias=True,
    max_seq_len=4096,
)
