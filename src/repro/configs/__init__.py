"""Config registry: one module per assigned architecture (+ paper models).

``get_config(name)`` returns the full-size ``ArchConfig``;
``get_smoke_config(name)`` returns a reduced same-family config for CPU tests.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (ArchConfig, MoEConfig, SSMConfig, ShapeSpec,
                                SHAPES, LM_SHAPES, shape_applicable,
                                TRAIN, PREFILL, DECODE)

from repro.configs import (chameleon_34b, zamba2_2_7b, stablelm_3b, qwen3_14b,
                           qwen2_0_5b, internlm2_1_8b, granite_moe_1b,
                           granite_moe_3b, mamba2_130m, whisper_base,
                           llama2_7b, opt_13b)

_MODULES = [chameleon_34b, zamba2_2_7b, stablelm_3b, qwen3_14b, qwen2_0_5b,
            internlm2_1_8b, granite_moe_1b, granite_moe_3b, mamba2_130m,
            whisper_base, llama2_7b, opt_13b]

CONFIGS: Dict[str, ArchConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}
SMOKE_CONFIGS: Dict[str, ArchConfig] = {
    m.CONFIG.name: m.SMOKE for m in _MODULES}

#: The ten assigned architecture ids (paper-extra models excluded).
ASSIGNED: List[str] = [
    "chameleon-34b", "zamba2-2.7b", "stablelm-3b", "qwen3-14b", "qwen2-0.5b",
    "internlm2-1.8b", "granite-moe-1b-a400m", "granite-moe-3b-a800m",
    "mamba2-130m", "whisper-base",
]


def get_config(name: str) -> ArchConfig:
    try:
        return CONFIGS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {sorted(CONFIGS)}")


def get_smoke_config(name: str) -> ArchConfig:
    try:
        return SMOKE_CONFIGS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; have {sorted(SMOKE_CONFIGS)}")


def get_shape(name: str) -> ShapeSpec:
    return SHAPES[name]


__all__ = ["ArchConfig", "MoEConfig", "SSMConfig", "ShapeSpec", "SHAPES",
           "LM_SHAPES", "CONFIGS", "SMOKE_CONFIGS", "ASSIGNED", "get_config",
           "get_smoke_config", "get_shape", "shape_applicable",
           "TRAIN", "PREFILL", "DECODE"]
