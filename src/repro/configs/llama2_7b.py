"""llama2-7b — the paper's primary validation model [arXiv:2307.09288].

Not one of the ten assigned archs; included because every TokenSim
validation figure (Figs. 4/5/9/10/11/13/14/15) uses it.
"""
from repro.configs.base import ArchConfig, DENSE

CONFIG = ArchConfig(
    name="llama2-7b",
    family=DENSE,
    num_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
    norm="rmsnorm",
    act="silu",
    rope_theta=10_000.0,
)

SMOKE = ArchConfig(
    name="llama2-7b-smoke",
    family=DENSE,
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=176,
    vocab_size=256,
    norm="rmsnorm",
    act="silu",
)
