"""mamba2-130m — attention-free SSD (state-space duality) [arXiv:2405.21060]."""
from repro.configs.base import ArchConfig, SSMConfig, SSM

CONFIG = ArchConfig(
    name="mamba2-130m",
    family=SSM,
    num_layers=24,
    d_model=768,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256, n_groups=1),
    norm="rmsnorm",
    pos_emb="none",
    tie_embeddings=True,
    subquadratic=True,
)

SMOKE = ArchConfig(
    name="mamba2-130m-smoke",
    family=SSM,
    num_layers=2,
    d_model=64,
    d_ff=0,
    vocab_size=256,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_width=4,
                  chunk_size=32, n_groups=1),
    norm="rmsnorm",
    pos_emb="none",
    tie_embeddings=True,
    subquadratic=True,
)
