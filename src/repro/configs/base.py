"""Architecture / shape configuration system.

One ``ArchConfig`` is the single source of truth for:
  * the real JAX model (``repro.models.model_zoo.build``),
  * the simulator's operator graph (``repro.core.costmodel.operators``),
  * the sharding plan (``repro.distributed.shard_plan``),
  * the roofline MODEL_FLOPS accounting.

Configs are frozen dataclasses so they are hashable (usable as jit static
arguments and dictionary keys for compilation caches).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Families
# ---------------------------------------------------------------------------
DENSE = "dense"
MOE = "moe"
SSM = "ssm"
HYBRID = "hybrid"
ENCDEC = "encdec"
VLM = "vlm"
AUDIO = "audio"

FAMILIES = (DENSE, MOE, SSM, HYBRID, ENCDEC, VLM, AUDIO)


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config (granite-style token-choice top-k)."""

    num_experts: int
    top_k: int
    d_expert: int                      # per-expert FFN hidden size
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3
    jitter_eps: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD sub-config (arXiv:2405.21060)."""

    d_state: int
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256
    n_groups: int = 1                 # B/C groups (GVA); 1 == multi-value attn

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ArchConfig:
    """A complete architecture description."""

    name: str
    family: str

    # Transformer trunk (decoder unless stated otherwise).
    num_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # Attention details.
    head_dim: int = 0                 # 0 -> d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_logit_softcap: float = 0.0
    rope_theta: float = 10_000.0
    pos_emb: str = "rope"             # rope | learned | sinusoidal | none

    # Block details.
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    act: str = "silu"                 # silu (-> SwiGLU MLP) | gelu (-> plain MLP)
    tie_embeddings: bool = False
    mlp_bias: bool = False

    # Sub-family configs.
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # Hybrid (zamba2-style): `attn_period` SSM layers share one attention
    # block; n_shared_attn distinct shared blocks round-robined.
    attn_period: int = 0
    n_shared_attn: int = 1

    # Encoder/decoder (whisper-style).
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    enc_seq_len: int = 0              # fixed encoder length for enc-dec decode shapes

    # Modality frontend stub: "none" (token ids) | "embed" (precomputed
    # frame/patch embeddings are the input).
    frontend: str = "none"

    # Limits / numerics.
    max_seq_len: int = 1_048_576
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # Is decode (autoregressive serve_step) defined for this arch?
    # (encoder-only archs would set False; all assigned archs decode.)
    supports_decode: bool = True
    # Sub-quadratic decode state => long_500k applies.
    subquadratic: bool = False

    # ------------------------------------------------------------------
    # Derived quantities
    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def gqa_group(self) -> int:
        return self.n_heads // max(1, self.n_kv_heads)

    def with_overrides(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (logical, unpadded) -------------------------
    def param_count(self) -> int:
        """Logical parameter count (no TP padding)."""
        d = self.d_model
        embed = self.vocab_size * d
        unembed = 0 if self.tie_embeddings else self.vocab_size * d
        if self.frontend == "embed" and self.family == AUDIO:
            embed = 0  # encoder input is an embedding stub

        def attn_params(n_heads, n_kv, head_dim, bias):
            p = d * n_heads * head_dim + 2 * d * n_kv * head_dim \
                + n_heads * head_dim * d
            if bias:
                p += (n_heads + 2 * n_kv) * head_dim
            return p

        def mlp_params(d_ff, gated):
            return d * d_ff * (3 if gated else 2)

        gated = self.act == "silu"
        layers = 0
        if self.family in (DENSE, VLM):
            per = attn_params(self.n_heads, self.n_kv_heads, self.head_dim,
                              self.qkv_bias) + mlp_params(self.d_ff, gated)
            layers = self.num_layers * (per + 2 * d)
        elif self.family == MOE:
            m = self.moe
            per = attn_params(self.n_heads, self.n_kv_heads, self.head_dim,
                              self.qkv_bias)
            per += m.num_experts * self.d_model * m.d_expert * (3 if gated else 2)
            per += d * m.num_experts  # router
            layers = self.num_layers * (per + 2 * d)
        elif self.family in (SSM, HYBRID):
            s = self.ssm
            d_in = s.d_inner(d)
            nheads = s.n_heads(d)
            conv_dim = d_in + 2 * s.n_groups * s.d_state
            per = d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)  # in_proj
            per += conv_dim * s.conv_width                              # conv1d
            per += nheads * 2                                           # A_log, D
            per += d_in                                                 # dt_bias lives in nheads; norm gate
            per += d_in * d                                             # out_proj
            per += d                                                    # norm
            layers = self.num_layers * per
            if self.family == HYBRID:
                shared = attn_params(self.n_heads, self.n_kv_heads,
                                     self.head_dim, self.qkv_bias)
                shared += mlp_params(self.d_ff, gated) + 2 * d
                layers += self.n_shared_attn * shared
        elif self.family in (ENCDEC, AUDIO):
            enc = self.n_enc_layers * (
                attn_params(self.n_heads, self.n_kv_heads, self.head_dim, True)
                + mlp_params(self.d_ff, False) + 2 * d)
            dec = self.n_dec_layers * (
                2 * attn_params(self.n_heads, self.n_kv_heads, self.head_dim,
                                True)
                + mlp_params(self.d_ff, False) + 3 * d)
            layers = enc + dec
        return embed + unembed + layers + d  # + final norm

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if self.family != MOE:
            return self.param_count()
        m = self.moe
        gated = self.act == "silu"
        inactive = self.num_layers * (m.num_experts - m.top_k) * \
            self.d_model * m.d_expert * (3 if gated else 2)
        return self.param_count() - inactive


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------
TRAIN = "train"
PREFILL = "prefill"
DECODE = "decode"


@dataclass(frozen=True)
class ShapeSpec:
    """One assigned (workload) shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        if self.kind == DECODE:
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


LM_SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, TRAIN),
    ShapeSpec("prefill_32k", 32_768, 32, PREFILL),
    ShapeSpec("decode_32k", 32_768, 128, DECODE),
    ShapeSpec("long_500k", 524_288, 1, DECODE),
)

SHAPES = {s.name: s for s in LM_SHAPES}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; else the reason to skip."""
    if shape.kind == DECODE and not cfg.supports_decode:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("long_500k requires sub-quadratic attention; "
                       f"{cfg.name} is full-attention (skip per assignment)")
    return True, ""


def pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
