"""internlm2-1.8b — dense decoder with GQA [arXiv:2403.17297]."""
from repro.configs.base import ArchConfig, DENSE

CONFIG = ArchConfig(
    name="internlm2-1.8b",
    family=DENSE,
    num_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92544,
    norm="rmsnorm",
    act="silu",
    rope_theta=1_000_000.0,
)

SMOKE = ArchConfig(
    name="internlm2-1.8b-smoke",
    family=DENSE,
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=192,
    vocab_size=384,
    norm="rmsnorm",
    act="silu",
)
