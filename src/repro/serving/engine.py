"""Real JAX serving engine: continuous batching + paged KV cache.

This is the ground-truth system the simulator is validated against (the
role vLLM/A100 plays in the paper).  Crucially it reuses the *same*
``BlockManager`` and ``ContinuousBatching`` scheduler classes as the
simulator, so structural validation (identical batch/memory traces) is a
meaningful exact test, and its measured iteration times calibrate the
simulator's ``TabularBackend`` for temporal validation.

Families: attention archs run the paged path (pages + block tables +
gather/pallas attention); SSM/hybrid/enc-dec run slot-based contiguous
caches (their decode state is O(1) or fixed — nothing to page).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costmodel.operators import BatchMix
from repro.core.mem.block_manager import BlockManager, MemoryConfig
from repro.core.mem.memory_pool import MemoryPool
from repro.core.request import Request, State
from repro.core.sched.local import make_local_scheduler
from repro.models import model_zoo as zoo
from repro.serving import paged_model
from repro.serving.sampling import sample_token


@dataclass
class EngineConfig:
    num_blocks: int = 256
    block_size: int = 16
    max_batch: int = 8
    max_batched_tokens: int = 2048
    max_pages_per_seq: int = 32
    local_policy: str = "continuous"
    attn_path: str = "gather"            # gather | pallas
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0
    max_mem_ratio: float = 1.0


@dataclass
class IterationRecord:
    mix: BatchMix
    wall: float
    t_virtual: float
    batch_ids: Tuple[int, ...]
    kind: str                            # prefill | decode


class ServingEngine:
    def __init__(self, model: zoo.Model, params, ec: EngineConfig,
                 pool: Optional[MemoryPool] = None, discipline=None):
        self.model = model
        self.params = params
        self.ec = ec
        #: tenant-aware queue ordering (repro.core.tenancy.qos); None=FIFO
        self.discipline = discipline
        self.paged = paged_model.supports_paged(model)

        mc = MemoryConfig(num_blocks=ec.num_blocks,
                          block_size=ec.block_size,
                          kv_bytes_per_token=1.0,
                          watermark=max(0.0, 1.0 - ec.max_mem_ratio))
        # scheduler shim state (same classes as the simulator's Worker)
        self.mem = BlockManager(mc)
        self.pool = pool
        self.waiting: deque = deque()
        self.running: List[Request] = []
        self.sched = make_local_scheduler(
            ec.local_policy, max_batch=ec.max_batch,
            max_batched_tokens=ec.max_batched_tokens)

        self.max_ctx = ec.max_pages_per_seq * ec.block_size
        if self.paged:
            # physical page `num_blocks` is the trash page for padded slots
            self.pages = paged_model.init_pages(
                model, ec.num_blocks + 1, ec.block_size, ec.max_batch,
                ec.max_pages_per_seq)
            self.trash_page = ec.num_blocks
        else:
            self.cache = zoo.init_cache(model, ec.max_batch, self.max_ctx)
            self.slot_of: Dict[int, int] = {}
            self.free_slots = list(range(ec.max_batch))[::-1]

        self.tokens_by_req: Dict[int, List[int]] = {}
        self.prompt_tokens: Dict[int, np.ndarray] = {}
        self.clock = 0.0                 # virtual time (sum of iter walls)
        self.records: List[IterationRecord] = []
        self.finished: List[Request] = []
        self._key = jax.random.key(ec.seed)

    # ------------------------------------------------------------------
    def add_request(self, req: Request, prompt_tokens=None) -> None:
        if prompt_tokens is None:
            rng = np.random.RandomState(req.id % (2 ** 31))
            prompt_tokens = rng.randint(
                0, self.model.plan.vocab_logical,
                size=(req.prompt_len,)).astype(np.int32)
        assert req.prompt_len + req.output_len <= self.max_ctx, \
            (req.prompt_len, req.output_len, self.max_ctx)
        self.prompt_tokens[req.id] = np.asarray(prompt_tokens, np.int32)
        self.tokens_by_req[req.id] = []
        req.state = State.WAITING
        self.waiting.append(req)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # -- waiting-queue protocol shared with core.worker.Worker ---------
    def next_waiting(self) -> Optional[Request]:
        if not self.waiting:
            return None
        if self.discipline is None:
            return self.waiting[0]
        return self.discipline.select(self.waiting, self.clock)

    def pop_waiting(self, req: Request) -> None:
        self.waiting.remove(req)

    def victim_sort_key(self):
        if self.discipline is None:
            return lambda r: (r.arrival_time, r.id)
        return self.discipline.victim_key(self.clock)

    # ------------------------------------------------------------------
    def step(self) -> Optional[IterationRecord]:
        plan = self.sched.plan(self)
        if plan.empty:
            return None
        for req in plan.admitted:
            req.state = State.PREFILL if req.remaining_prefill else \
                State.DECODE
            if req not in self.running:
                self.running.append(req)
            if self.discipline is not None:
                self.discipline.on_service_start(req, self.clock)
            if self.paged:
                pass                     # block table comes from self.mem
            else:
                self.slot_of[req.id] = self.free_slots.pop()
        for req in plan.preempted:
            req.state = State.PREEMPTED
            if req in self.running:
                self.running.remove(req)
            if not self.paged and req.id in self.slot_of:
                self.free_slots.append(self.slot_of.pop(req.id))
            self.waiting.appendleft(req)

        for req in plan.decode:
            self.mem.append_tokens(req, 1)

        t0 = time.perf_counter()
        if plan.prefill:
            self._run_prefill(plan)
            kind = "prefill"
            batch = tuple(r.id for r, _, _ in plan.prefill)
        else:
            self._run_decode(plan)
            kind = "decode"
            batch = tuple(r.id for r in plan.decode)
        wall = time.perf_counter() - t0

        mix = BatchMix.from_batch(
            [(c, b) for _, c, b in plan.prefill],
            [r.context_len for r in plan.decode])
        self.clock += wall
        rec = IterationRecord(mix=mix, wall=wall, t_virtual=self.clock,
                              batch_ids=batch, kind=kind)
        self.records.append(rec)

        now = self.clock
        for req, chunk, _ in plan.prefill:
            req.prefill_done_len = max(req.cached_len,
                                       req.prefill_done_len) + chunk
            if req.remaining_prefill == 0:
                self._emit(req, now)
        for req in plan.decode:
            self._emit(req, now)
        return rec

    def run(self, max_steps: int = 1_000_000) -> None:
        steps = 0
        while self.has_work and steps < max_steps:
            if self.step() is None:
                break
            steps += 1

    # ------------------------------------------------------------------
    def _emit(self, req: Request, now: float) -> None:
        first = req.tokens_generated == 0
        req.tokens_generated += 1
        req.token_times.append(now)
        if first:
            req.t_first_token = now
        req.state = State.DECODE
        if req.finished:
            req.state = State.FINISHED
            req.t_finish = now
            self.running.remove(req)
            self.mem.free(req)
            if self.pool is not None:
                self.pool.store(req.session_id, req.context_len)
            if not self.paged:
                self.free_slots.append(self.slot_of.pop(req.id))
            self.finished.append(req)

    # -- prefill -----------------------------------------------------------
    def _full_sequence(self, req: Request) -> np.ndarray:
        return np.concatenate([
            self.prompt_tokens[req.id],
            np.asarray(self.tokens_by_req[req.id], np.int32)])

    @staticmethod
    def _bucket(n: int) -> int:
        """Pad prompt lengths to power-of-two buckets so the jit cache
        holds O(log max_ctx) prefill programs, not one per length."""
        return max(8, 1 << (int(n) - 1).bit_length())

    def _run_prefill(self, plan) -> None:
        for req, chunk, ctx in plan.prefill:
            seq = self._full_sequence(req)[:ctx + chunk]
            plen = int(seq.shape[0])
            spad = min(self._bucket(plen), self.max_ctx)
            padded = np.zeros((1, spad), np.int32)
            padded[0, :plen] = seq
            toks = jnp.asarray(padded)
            if self.paged:
                last_logits, k, v = paged_model.prefill_collect(
                    self.model, self.params, toks, plen)
                table = np.full((self.ec.max_pages_per_seq,),
                                self.trash_page, np.int32)
                blocks = self.mem.block_table(req)
                table[:len(blocks)] = blocks
                self.pages = paged_model.scatter_prefill(
                    self.model, self.pages, k, v,
                    jnp.asarray(table), plen)
            else:
                slot = self.slot_of[req.id]
                cache1 = zoo.init_cache(self.model, 1, self.max_ctx)
                batch = {"tokens": toks}
                if self.model.cfg.family in ("audio", "encdec"):
                    batch["embeds"] = self._enc_embeds(req)[None]
                logits, cache1 = self._prefill_slot_fn(
                    self.model, self.params, batch, cache1)
                last_logits = logits[0, plen - 1]
                self._write_slot(slot, cache1, plen)
            tok = self._sample(last_logits)
            self.tokens_by_req[req.id].append(tok)
            self._slot_write_len(req, plen)

    _prefill_slot_fn = staticmethod(
        jax.jit(zoo.prefill, static_argnums=0))
    _decode_slot_fn = staticmethod(
        jax.jit(zoo.decode_step, static_argnums=0))

    def _enc_embeds(self, req: Request):
        rng = np.random.RandomState((req.id + 7919) % (2 ** 31))
        return jnp.asarray(rng.randn(
            self.model.cfg.enc_seq_len,
            self.model.cfg.d_model).astype(np.float32))

    def _write_slot(self, slot: int, cache1, length: int) -> None:
        """Copy a single-request contiguous cache into batch slot."""
        def upd(dst, src):
            if dst.ndim >= 2 and dst.shape[1] == self.ec.max_batch:
                return dst.at[:, slot].set(src[:, 0])
            return dst
        for key in self.cache:
            if key == "len":
                continue
            self.cache[key] = upd(self.cache[key], cache1[key])

    def _slot_write_len(self, req: Request, length: int) -> None:
        if not self.paged:
            slot = self.slot_of[req.id]
            self.cache["len"] = self.cache["len"].at[slot].set(length)

    # -- decode ------------------------------------------------------------
    def _run_decode(self, plan) -> None:
        reqs = plan.decode
        if self.paged:
            bsz = self.ec.max_batch
            tables = np.full((bsz, self.ec.max_pages_per_seq),
                             self.trash_page, np.int32)
            lens = np.zeros((bsz,), np.int32)
            toks = np.zeros((bsz,), np.int32)
            for i, r in enumerate(reqs):
                bt = self.mem.block_table(r)
                tables[i, :len(bt)] = bt
                lens[i] = r.context_len - 1      # KV before this token
                toks[i] = self._current_token(r)
            self.pages = {**self.pages,
                          "tables": jnp.asarray(tables),
                          "len": jnp.asarray(lens)}
            logits, self.pages = paged_model.paged_decode_step(
                self.model, self.params, self.pages,
                jnp.asarray(toks), self.ec.attn_path)
            for i, r in enumerate(reqs):
                self.tokens_by_req[r.id].append(self._sample(logits[i]))
        else:
            toks = np.zeros((self.ec.max_batch,), np.int32)
            lens = np.array(self.cache["len"])
            for r in reqs:
                slot = self.slot_of[r.id]
                toks[slot] = self._current_token(r)
                lens[slot] = r.context_len - 1
            self.cache["len"] = jnp.asarray(lens)
            logits, self.cache = self._decode_slot_fn(
                self.model, self.params, self.cache, jnp.asarray(toks))
            for r in reqs:
                self.tokens_by_req[r.id].append(
                    self._sample(logits[self.slot_of[r.id]]))

    def _current_token(self, req: Request) -> int:
        gen = self.tokens_by_req[req.id]
        if gen:
            return int(gen[-1])
        return int(self.prompt_tokens[req.id][-1])

    def _sample(self, logits) -> int:
        self._key, sub = jax.random.split(self._key)
        return int(sample_token(logits, sub, greedy=self.ec.greedy,
                                temperature=self.ec.temperature,
                                vocab_logical=self.model.plan.vocab_logical))
