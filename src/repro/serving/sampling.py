"""Token sampling (greedy / temperature), padded-vocab aware."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("greedy", "vocab_logical"))
def sample_token(logits, key, *, greedy: bool = True,
                 temperature: float = 1.0, vocab_logical: int = 0):
    """logits: (V_phys,). Returns an int32 token id < vocab_logical."""
    logits = logits.astype(jnp.float32)
    if vocab_logical and vocab_logical < logits.shape[-1]:
        mask = jnp.arange(logits.shape[-1]) >= vocab_logical
        logits = jnp.where(mask, -1e30, logits)
    if greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits / jnp.maximum(temperature, 1e-6)).astype(jnp.int32)
