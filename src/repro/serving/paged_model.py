"""Paged-KV model execution for the real serving engine.

The decode step runs against KV stored in fixed-size pages selected by a
block table — the runtime realization of the PagedAttention mechanism the
simulator's BlockManager models.  Two attention paths:

* ``gather``  — jnp: gather the sequence's pages and run masked decode
                attention (fast on CPU, what the tests use),
* ``pallas``  — the ``repro.kernels.paged_attention`` TPU kernel.

Supported families: attention-based (dense / moe / vlm).  SSM/hybrid have
O(1) decode state (nothing to page); enc-dec serving uses the contiguous
path.  The engine falls back to ``model_zoo.decode_step`` slot caches for
those (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import DENSE, MOE, VLM
from repro.models import model_zoo as zoo
from repro.models.attention_impl import decode_attention
from repro.models.layers import norm_apply

PAGED_FAMILIES = (DENSE, MOE, VLM)


def supports_paged(model: zoo.Model) -> bool:
    return model.cfg.family in PAGED_FAMILIES


# ---------------------------------------------------------------------------
# Page store
# ---------------------------------------------------------------------------
def init_pages(model: zoo.Model, num_pages: int, page_size: int,
               max_batch: int, max_pages_per_seq: int) -> Dict:
    cfg, plan = model.cfg, model.plan
    cd = model.compute_dtype
    shape = (cfg.num_layers, num_pages, page_size, plan.n_kv, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, cd),
        "v": jnp.zeros(shape, cd),
        # per-slot block table + context len (padded rows are inactive)
        "tables": jnp.zeros((max_batch, max_pages_per_seq), jnp.int32),
        "len": jnp.zeros((max_batch,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Prefill: run the contiguous forward, then scatter KV into pages
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnums=0)
def prefill_collect(model: zoo.Model, params, tokens, prompt_len):
    """tokens: (1, S) with S a padded bucket; ``prompt_len`` (dynamic)
    marks the real prompt.  Bucketing keeps the jit cache small — one
    compile per power-of-two bucket, not one per prompt length.

    Returns (logits_at_last_real_token (V,), k, v (L,S,Hkv,hd))."""
    cache = zoo.init_cache(model, 1, tokens.shape[1])
    logits, cache = zoo.prefill(model, params, {"tokens": tokens}, cache)
    k = cache["k"][:, 0]
    v = cache["v"][:, 0]
    last = jax.lax.dynamic_index_in_dim(logits[0], prompt_len - 1, 0,
                                        keepdims=False)
    return last, k, v


@functools.partial(jax.jit, static_argnums=0)
def scatter_prefill(model: zoo.Model, pages, k, v, table_row, prompt_len):
    """Write one request's prefill KV (L,S,Hkv,hd) into its pages.

    table_row: (MP,) physical page ids covering the prompt. Positions at
    or beyond ``prompt_len`` (bucket padding) land in the trash page
    (last physical page, reserved by the engine)."""
    page_size = pages["k"].shape[2]
    trash = pages["k"].shape[1] - 1
    s = k.shape[1]
    pos = jnp.arange(s)
    page_idx = jnp.where(pos < prompt_len,
                         table_row[jnp.minimum(pos // page_size,
                                               table_row.shape[0] - 1)],
                         trash)
    offset = pos % page_size
    # Adjacent advanced indices keep the L axis leading: target positions
    # are (L, S, Hkv, hd).
    pk = pages["k"].at[:, page_idx, offset].set(k.astype(pages["k"].dtype))
    pv = pages["v"].at[:, page_idx, offset].set(v.astype(pages["v"].dtype))
    return {**pages, "k": pk, "v": pv}


# ---------------------------------------------------------------------------
# Paged decode step
# ---------------------------------------------------------------------------
def _attn_decode_paged(p, x_t, model: zoo.Model, k_pages, v_pages, tables,
                       lens, *, attn_path: str):
    """x_t: (B,1,d); k/v_pages: (NP,page,Hkv,hd); tables: (B,MP);
    lens: (B,) context length *before* this token.
    Returns (out (B,1,d), k_pages, v_pages)."""
    cfg = model.cfg
    bsz = x_t.shape[0]
    page = k_pages.shape[1]
    positions = lens[:, None]
    q = zoo._q_proj(p, x_t, model, positions)            # (B,1,H,hd)
    k_t, v_t = zoo._kv_proj(p, x_t, model, positions)    # (B,1,Hkv,hd)

    prow = tables[jnp.arange(bsz), lens // page]         # (B,)
    off = lens % page
    k_pages = k_pages.at[prow, off].set(k_t[:, 0].astype(k_pages.dtype))
    v_pages = v_pages.at[prow, off].set(v_t[:, 0].astype(v_pages.dtype))
    valid = lens + 1

    if attn_path == "pallas":
        from repro.kernels.paged_attention import ops as paged_ops
        ctx = paged_ops.paged_attention(q[:, 0], k_pages, v_pages,
                                        tables, valid)[:, None]
    else:
        mp = tables.shape[1]
        k_seq = k_pages[tables].reshape(bsz, mp * page, *k_pages.shape[2:])
        v_seq = v_pages[tables].reshape(bsz, mp * page, *v_pages.shape[2:])
        ctx = decode_attention(q, k_seq, v_seq, valid,
                               logit_softcap=cfg.attn_logit_softcap)
    out = zoo._attn_out(p, ctx, model)
    return out, k_pages, v_pages


@functools.partial(jax.jit, static_argnums=(0, 4))
def paged_decode_step(model: zoo.Model, params, pages, tokens,
                      attn_path: str = "gather"):
    """One decode iteration over the whole running batch.

    tokens: (B,) current token per slot (padded slots: anything).
    Returns (logits (B,V), pages with lens advanced)."""
    cfg = model.cfg
    lens = pages["len"]
    tables = pages["tables"]
    x = zoo._embed_tokens(model, params, tokens[:, None])
    if cfg.pos_emb == "learned":
        x = x + params["pos"][lens][:, None].astype(x.dtype)

    def body(x_t, inp):
        lp, kp, vp = inp
        h = norm_apply(lp["ln1"], x_t, cfg.norm)
        attn, kp, vp = _attn_decode_paged(lp["attn"], h, model, kp, vp,
                                          tables, lens,
                                          attn_path=attn_path)
        x_t = x_t + attn
        h = norm_apply(lp["ln2"], x_t, cfg.norm)
        y, _ = zoo._ffn_apply(lp, h, model)
        return x_t + y, (kp, vp)

    x, (pk, pv) = jax.lax.scan(body, x, (params["layers"],
                                         pages["k"], pages["v"]))
    logits = zoo._lm_head(model, params, x)[:, 0]
    return logits, {**pages, "k": pk, "v": pv, "len": lens + 1}
