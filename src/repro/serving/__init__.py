from repro.serving.engine import EngineConfig, ServingEngine  # noqa: F401
