from repro.kernels.paged_attention import ops, ref  # noqa: F401
