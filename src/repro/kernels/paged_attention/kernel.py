"""Paged decode attention — Pallas TPU kernel.

This is the TPU adaptation of the PagedAttention hot loop that vLLM (and
therefore TokenSim's memory model) is built around.  On GPU the kernel is
a warp-level gather over 16-token pages; the TPU-idiomatic analogue is:

* KV pages live in HBM as ``(Hkv, num_pages, page_size, D)``; the *block
  table* (logical->physical page map, the PagedAttention data structure)
  is a **scalar-prefetch** operand, so Mosaic can compute each grid step's
  page address early and overlap the page DMA with compute — gather
  becomes "DMA whole pages into VMEM", the coalesced-load analogue.
* Grid ``(B, Hkv, max_pages)``; each step attends one page.  All Q heads
  of one GQA group ride together as a ``(group, D)`` tile so the
  score matmul is ``(group × D) @ (D × page)`` on the MXU instead of a
  per-head matvec.
* Pages past ``ceil(context_len / page_size)`` are skipped with
  ``pl.when`` — requests only pay for the KV they actually hold, which is
  exactly the behavior TokenSim's block-granular memory manager models.

Validated in interpret mode against ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_attn_kernel(bt_ref, cl_ref, q_ref, k_ref, v_ref, o_ref,
                       acc_ref, m_ref, l_ref, *,
                       page_size: int, max_pages: int, scale: float):
    b = pl.program_id(0)
    pi = pl.program_id(2)

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    ctx_len = cl_ref[b]
    page_start = pi * page_size

    @pl.when(page_start < ctx_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                  # (group, D)
        k = k_ref[0, 0].astype(jnp.float32)               # (page, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = page_start + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)                        # (group, page)
        s = jnp.where(pos < ctx_len, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[:, 0] = m_new
        l_ref[:, 0] = l_new

    @pl.when(pi == max_pages - 1)
    def _finish():
        l = l_ref[:, 0]
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def paged_attention_fwd(q, k_pages, v_pages, block_tables, context_lens, *,
                        interpret: bool = False):
    """q: (B, Hq, D) one decode token per sequence;
    k_pages/v_pages: (Hkv, num_pages, page_size, D);
    block_tables: (B, max_pages) int32 physical page ids;
    context_lens: (B,) int32.  Returns (B, Hq, D).
    """
    b, hq, d = q.shape
    hkv, _, page_size, _ = k_pages.shape
    group = hq // hkv
    max_pages = block_tables.shape[1]
    grid = (b, hkv, max_pages)

    kernel = functools.partial(_paged_attn_kernel, page_size=page_size,
                               max_pages=max_pages, scale=d ** -0.5)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            # q: all heads of the kv group together
            pl.BlockSpec((1, group, d),
                         lambda b_, h_, pi, bt, cl: (b_, h_, 0)),
            # k/v: the physical page picked by the prefetched block table
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b_, h_, pi, bt, cl: (h_, bt[b_, pi], 0, 0)),
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b_, h_, pi, bt, cl: (h_, bt[b_, pi], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, group, d),
                               lambda b_, h_, pi, bt, cl: (b_, h_, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
    )

    # (B, Hq, D) stays as-is; the (1, group, d) BlockSpec tiles the head
    # axis by GQA groups (q heads of kv head h are contiguous: h*group..).
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, d), q.dtype),
        interpret=interpret,
    )(block_tables, context_lens, q, k_pages, v_pages)
