"""Pure-jnp oracle for paged decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q, k_pages, v_pages, block_tables, context_lens):
    """Same signature/layout as the kernel:
    q (B,Hq,D); k/v_pages (Hkv,NP,P,D); block_tables (B,MP); lens (B,).
    Gathers each sequence's pages into a contiguous cache, then does
    masked softmax attention."""
    b, hq, d = q.shape
    hkv, _, page, _ = k_pages.shape
    mp = block_tables.shape[1]
    group = hq // hkv

    # gather: (B, Hkv, MP*P, D)
    k_seq = jnp.moveaxis(k_pages[:, block_tables], 0, 1) \
        .reshape(b, hkv, mp * page, d)
    v_seq = jnp.moveaxis(v_pages[:, block_tables], 0, 1) \
        .reshape(b, hkv, mp * page, d)
    k_seq = jnp.repeat(k_seq, group, axis=1)
    v_seq = jnp.repeat(v_seq, group, axis=1)

    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32),
                   k_seq.astype(jnp.float32)) * (d ** -0.5)
    valid = jnp.arange(mp * page)[None, None, :] < \
        context_lens[:, None, None]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhk,bhkd->bhd", p, v_seq.astype(jnp.float32))
    return o.astype(q.dtype)
