"""Jit'd public wrapper for paged decode attention.

Natural serving layout in: q (B,H,D), pages (num_pages, page_size, Hkv, D)
(token-major, what the serving engine appends into), block tables and
context lens. The wrapper transposes pages to the kernel's head-major
layout; on TPU that transpose is fused away by XLA when the cache is
already stored head-major (the serving engine stores head-major on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention_fwd


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_tables, context_lens, *,
                    interpret: bool | None = None):
    """q: (B,H,D); k/v_pages: (NP, page, Hkv, D) -> (B,H,D)."""
    if interpret is None:
        interpret = _is_cpu()
    kh = jnp.transpose(k_pages, (2, 0, 1, 3))      # (Hkv, NP, page, D)
    vh = jnp.transpose(v_pages, (2, 0, 1, 3))
    return paged_attention_fwd(q, kh, vh,
                               block_tables.astype(jnp.int32),
                               context_lens.astype(jnp.int32),
                               interpret=interpret)
