"""Jit'd public wrapper for the SSD scan kernel.

Pads S up to a chunk multiple with identity steps (dA_log = 0, x = 0: the
state passes through unchanged and padded y rows are sliced off).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_fwd


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xbar, dA_log, Bm, Cm, *, chunk: int = 256,
             interpret: bool | None = None):
    """xbar (B,S,H,P); dA_log (B,S,H); Bm/Cm (B,S,G,N) ->
    (y (B,S,H,P) f32, final_state (B,H,N,P) f32)."""
    if interpret is None:
        interpret = _is_cpu()
    b, s, h, p = xbar.shape
    chunk = min(chunk, s) if s % chunk == 0 or s < chunk else chunk
    pad = (-s) % chunk
    if pad:
        xbar = jnp.pad(xbar.astype(jnp.float32),
                       ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA_log = jnp.pad(dA_log.astype(jnp.float32),
                         ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm.astype(jnp.float32),
                     ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm.astype(jnp.float32),
                     ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, fs = ssd_scan_fwd(xbar.astype(jnp.float32),
                         dA_log.astype(jnp.float32),
                         Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                         chunk=chunk, interpret=interpret)
    return y[:, :s], fs
