"""Oracle for the SSD scan kernel: the token-by-token recurrence."""
from __future__ import annotations

from repro.models.ssm import ssd_recurrent


def ssd_scan_ref(xbar, dA_log, Bm, Cm):
    """Same contract as the kernel; returns (y, final_state) in fp32."""
    return ssd_recurrent(xbar, dA_log, Bm, Cm)
