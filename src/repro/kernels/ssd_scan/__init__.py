from repro.kernels.ssd_scan import ops, ref  # noqa: F401
