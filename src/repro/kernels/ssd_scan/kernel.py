"""Chunked SSD (Mamba2) scan — Pallas TPU kernel.

The SSD duality makes the within-chunk work two (L×L)·(L×P) matmuls —
exactly what the MXU wants — while the cross-chunk recurrence is a tiny
(N×P) state update carried in VMEM scratch:

* Grid ``(B, H, NC)``, chunk axis innermost/sequential; the fp32 state
  ``(N, P)`` persists in VMEM scratch across the chunk sweep of one
  (batch, head) — the HBM traffic is exactly one read of x/B/C/dt and one
  write of y (plus the final state), i.e. the kernel is I/O-minimal.
* B/C are grouped (GVA): the index map sends head h to group
  ``h // (H/G)`` — no repeated B/C in HBM.
* Block shapes: L=chunk_size (default 256) rows × P/N lanes; with
  P=64, N=128, L=256 the working set is ~0.6 MB fp32 — far under VMEM,
  leaving room for Mosaic's double buffering.

Validated in interpret mode against ``ref.py`` (recurrent oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, da_ref, b_ref, c_ref, y_ref, fs_ref, state_ref, *,
                num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (L, P)
    la = da_ref[0, :, 0].astype(jnp.float32)           # (L,)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)         # (L, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)         # (L, N)
    L = x.shape[0]

    seg = jnp.cumsum(la)                               # (L,)  includes self
    total = seg[-1]

    # ---- within-chunk: (scores ⊙ decay) @ x ---------------------------
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    li = seg[:, None]
    lj = seg[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.where(tri, jnp.exp(li - lj), 0.0)
    y = jax.lax.dot_general(scores * decay, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # ---- inter-chunk: y += exp(seg) * C @ state_in --------------------
    state_in = state_ref[...]                          # (N, P)
    y = y + jnp.exp(seg)[:, None] * jax.lax.dot_general(
        Cm, state_in, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # ---- state update: S = S*exp(total) + (w*B)^T @ x -----------------
    w = jnp.exp(total - seg)                           # (L,)
    state_ref[...] = state_in * jnp.exp(total) + jax.lax.dot_general(
        Bm * w[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == num_chunks - 1)
    def _finish():
        fs_ref[0, 0] = state_ref[...]


def ssd_scan_fwd(xbar, dA_log, Bm, Cm, *, chunk: int,
                 interpret: bool = False):
    """xbar: (B,S,H,P) fp32 dt-scaled inputs; dA_log: (B,S,H);
    Bm/Cm: (B,S,G,N).  Returns (y (B,S,H,P) f32, final_state (B,H,N,P) f32).
    S must be a multiple of ``chunk`` (ops.py pads)."""
    b, s, h, p = xbar.shape
    g, n = Bm.shape[2], Bm.shape[3]
    hpg = h // g
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    grid = (b, h, nc)

    kernel = functools.partial(_ssd_kernel, num_chunks=nc)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p),
                         lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, chunk, 1),
                         lambda b_, h_, ci: (b_, ci, h_)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda b_, h_, ci: (b_, ci, h_ // hpg, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda b_, h_, ci: (b_, ci, h_ // hpg, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p),
                         lambda b_, h_, ci: (b_, ci, h_, 0)),
            pl.BlockSpec((1, 1, n, p),
                         lambda b_, h_, ci: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xbar, dA_log, Bm, Cm)
