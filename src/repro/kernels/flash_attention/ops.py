"""Jit'd public wrapper for the flash attention kernel.

Accepts the model-layout tensors q:(B,Sq,H,D), k/v:(B,Skv,Hkv,D), pads the
head_dim to a multiple of 128 (MXU lane width) and seq lens to the block
size, transposes to head-major, runs the kernel, and undoes the padding.

On CPU (this container) the kernel runs in interpret mode; on TPU it
compiles to Mosaic. The flag is automatic from the backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd


def _is_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "softcap", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_kv: int = 512, softcap: float = 0.0,
                    interpret: bool | None = None):
    """Model layout in/out: q (B,Sq,H,D) -> (B,Sq,H,D)."""
    if interpret is None:
        interpret = _is_cpu()
    b, sq, h, d = q.shape
    skv = k.shape[1]

    qh = jnp.moveaxis(q, 2, 1)                 # (B,H,Sq,D)
    kh = jnp.moveaxis(k, 2, 1)
    vh = jnp.moveaxis(v, 2, 1)

    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    qh = _pad_to(qh, bq, 2)
    kh = _pad_to(kh, bkv, 2)
    vh = _pad_to(vh, bkv, 2)
    d_pad = (-d) % 128 if not interpret else 0
    if d_pad:
        qh = _pad_to(qh, d + d_pad, 3)
        kh = _pad_to(kh, d + d_pad, 3)
        vh = _pad_to(vh, d + d_pad, 3)
        # padded q columns are zeros => scores unchanged; but the softmax
        # scale must use the padded d inside the kernel, so rescale q.
        qh = qh * ((d + d_pad) / d) ** 0.5

    # KV padding beyond skv must never win the softmax: causal masks it
    # (padded kv positions exceed every real q position when sq == skv);
    # non-causal passes kv_len so the kernel masks the padded tail.
    out = flash_attention_fwd(qh, kh, vh, causal=causal, block_q=bq,
                              block_kv=bkv, softcap=softcap, kv_len=skv,
                              interpret=interpret)
    out = out[:, :, :sq, :d]
    return jnp.moveaxis(out, 1, 2)
