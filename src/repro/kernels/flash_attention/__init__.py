from repro.kernels.flash_attention import ops, ref  # noqa: F401
