"""Flash attention forward — Pallas TPU kernel.

TPU-native tiling (not a CUDA port):

* Grid ``(B, Hq, NQ, NK)``; the KV axis is the innermost (sequential)
  dimension so the online-softmax state lives in VMEM scratch across the
  KV sweep for one (batch, head, q-block) and output is written exactly
  once, on the final KV step.
* BlockSpecs DMA one ``(block_q, head_dim)`` Q tile and one
  ``(block_kv, head_dim)`` K/V tile from HBM into VMEM per step; the MXU
  sees (block_q × head_dim) @ (head_dim × block_kv) matmuls with both
  dims padded to the 128-lane register layout by the caller (ops.py).
* GQA is expressed in the K/V index maps (q head h reads kv head
  ``h // group``) — no repeated KV materialization in HBM.
* Causal masking: whole KV tiles strictly above the diagonal are skipped
  with ``pl.when`` (no FLOPs; Mosaic elides the unused DMA); the diagonal
  tile is masked element-wise.

Validated in interpret mode against ``ref.py`` (pure jnp).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                      causal: bool, block_q: int, block_kv: int,
                      num_kv_blocks: int, softcap: float, kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_kv

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)               # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        d = q.shape[-1]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * (d ** -0.5)                               # (bq, bk)
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_kv), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_kv), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        elif kv_len < num_kv_blocks * block_kv:
            # non-causal with padded KV tail: mask the padding
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_kv), 1)
            s = jnp.where(kpos < kv_len, s, NEG_INF)

        m_prev = m_ref[:, 0]                              # (bq,)
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[:, 0] = m_new
        l_ref[:, 0] = l_new

    if causal:
        # Skip KV tiles strictly above the diagonal.
        pl.when(k_start <= q_start + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        l = l_ref[:, 0]
        o_ref[0, 0, :, :] = (acc_ref[...] /
                             jnp.maximum(l, 1e-30)[:, None]
                             ).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool, block_q: int = 512,
                        block_kv: int = 512, softcap: float = 0.0,
                        kv_len: int = 0, interpret: bool = False):
    """q: (B,H,Sq,D); k/v: (B,Hkv,Skv,D). Head-major layout (caller
    transposes) so each BlockSpec tile is a contiguous (seq, head_dim)
    plane. Shapes must tile exactly (ops.py pads; ``kv_len`` is the
    unpadded KV length for non-causal masking)."""
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = h // hkv
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0, (sq, skv)
    nq, nk = sq // block_q, skv // block_kv
    grid = (b, h, nq, nk)

    kernel = functools.partial(
        _flash_fwd_kernel, causal=causal, block_q=block_q,
        block_kv=block_kv, num_kv_blocks=nk, softcap=softcap,
        kv_len=kv_len or skv)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h_, qi, ki: (b_, h_ // group, ki, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda b_, h_, qi, ki: (b_, h_ // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # output accumulator
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running denominator
        ],
        interpret=interpret,
    )(q, k, v)
