"""Pure-jnp oracle for the flash attention kernel (head-major layout)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool, softcap: float = 0.0):
    """q: (B,H,Sq,D); k/v: (B,Hkv,Skv,D) -> (B,H,Sq,D)."""
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
